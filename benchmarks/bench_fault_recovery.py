"""Fault recovery: WOC vs Cabinet under replica crashes (repro.faults).

The paper's heterogeneity story under failure: Cabinet serializes every
operation through its top-weighted leader, so its failure sensitivity is
ROLE-shaped — losing the leader is a full outage until re-election,
losing a low-weight follower barely registers (clients never talk to
it). WOC spreads coordination across all replicas, so its sensitivity is
CLIENT-shaped and uniform: any crash costs roughly the client-retry
constant regardless of the victim's weight, and no replica is
privileged. A degrade pair (top-weight node's network inflated 8x, then
healed) probes the same story without killing anyone: WOC's dynamic
weights shift quorums off the slow node, while Cabinet's leader IS the
slow node.

Every scenario is a deterministic simulation: dips, time-to-recover and
effective downtime are exact functions of seed + schedule, so claims
here are hard checks, not wall-clock notes. Each run's history is
verified linearizable before any number is reported — an unverified
recovery curve is worthless.
"""

import pathlib

from benchmarks.common import Claims, write_csv, write_json

from repro.core.simulator import Workload
from repro.faults import Crash, Degrade, Recover, resolve_node
from repro.obs import analyze_events, write_trace
from repro.scenario import Observability, Scenario, run_scenario
from repro.verify import (check_history_linearizable, effective_downtime,
                          recovery_report)

WORKLOAD = Workload(p_independent=0.8, p_common=0.1, p_hot=0.1,
                    n_hot_objects=4, reads_fraction=0.2)


def _scenario(proto: str, name: str, faults, fault_at: float,
              total_ops: int, claims: Claims, obs=None) -> tuple:
    art = run_scenario(
        Scenario(protocol=proto, total_ops=total_ops, batch_size=10,
                 n_clients=4, workload=WORKLOAD, faults=faults, seed=5,
                 obs=obs))
    r = art.result
    ok, why = check_history_linearizable(r.history)
    claims.check(f"{proto}/{name}: all ops commit, history linearizable",
                 ok and r.committed_ops == total_ops,
                 f"committed={r.committed_ops}/{total_ops} "
                 f"{'ok' if ok else why}")
    rep = recovery_report(r.history, fault_at)
    return r, {"protocol": proto, "scenario": name,
            "ops": r.committed_ops, "makespan_s": round(r.makespan_s, 4),
            "tx_s": round(r.throughput_tx_s, 1),
            "baseline_tx_s": round(rep.baseline_tx_s, 1),
            "dip_tx_s": round(rep.dip_tx_s, 1),
            "dip_frac": round(rep.dip_frac, 4),
            "ttr_s": round(rep.time_to_recover_s, 4),
            "downtime_s": round(effective_downtime(r.history, fault_at), 4),
            "recovered": rep.recovered,
            "fast_frac": round(r.fast_path_frac, 4)}


def run_bench(out_dir, quick: bool = False,
              trace: bool = False) -> list[str]:
    claims = Claims()
    total = 10_000 if quick else 30_000
    at = 0.05 if quick else 0.15
    rec = 0.2 if quick else 0.35
    heal = 0.25 if quick else 0.45

    crash_of = {"crash_low": (Crash(at, "low_weight"),
                              Recover(rec, "low_weight")),
                "crash_top": (Crash(at, "top_weight"),
                              Recover(rec, "top_weight"))}
    degrade = {"degrade_top": (Degrade(at, "top_weight", 8.0),
                               Degrade(heal, "top_weight", 1.0))}

    rows = []
    by = {}
    deg_trace = None
    for proto in ("woc", "cabinet"):
        for name, faults in {**crash_of, **degrade}.items():
            # the recovery-timeline trace: op-level spans for the WOC
            # degrade run feed the critical-path attribution claim below
            # (recording is host-side only, so the numbers are identical
            # with tracing on)
            obs = (Observability(trace=True)
                   if (proto, name) == ("woc", "degrade_top") else None)
            r, row = _scenario(proto, name, faults, at, total, claims,
                               obs=obs)
            if obs is not None:
                deg_trace = r.trace
            rows.append(row)
            by[(proto, name)] = row

    # -- the heterogeneity-under-failure story -------------------------------
    woc_low, woc_top = by[("woc", "crash_low")], by[("woc", "crash_top")]
    cab_low, cab_top = by[("cabinet", "crash_low")], by[("cabinet",
                                                         "crash_top")]
    claims.check(
        "Cabinet's crash sensitivity is role-shaped: leader (top-weight) "
        "crash is a hard outage, follower (low-weight) crash barely "
        "registers (>= 4x faster recovery)",
        cab_top["dip_frac"] == 0.0
        and cab_low["ttr_s"] * 4 <= cab_top["ttr_s"],
        f"ttr top={cab_top['ttr_s']:.3f}s low={cab_low['ttr_s']:.3f}s "
        f"dip top={cab_top['dip_frac']:.2f}")
    claims.check(
        "WOC has no privileged replica: top-weight and low-weight crash "
        "recoveries are within 2x of each other (Cabinet's differ >= 4x)",
        woc_low["ttr_s"] <= 2 * woc_top["ttr_s"]
        and woc_top["ttr_s"] <= 2 * woc_low["ttr_s"],
        f"woc ttr top={woc_top['ttr_s']:.3f}s low={woc_low['ttr_s']:.3f}s")
    claims.check(
        "Victim weight moves Cabinet's recovery time but not WOC's: "
        "cabinet ttr(top) > ttr(low); woc's two ttrs within two 50ms "
        "measurement windows of each other",
        cab_top["ttr_s"] > cab_low["ttr_s"]
        and abs(woc_top["ttr_s"] - woc_low["ttr_s"]) <= 0.1 + 1e-9,
        f"woc |{woc_top['ttr_s']:.3f}-{woc_low['ttr_s']:.3f}| "
        f"cabinet {cab_top['ttr_s']:.3f}>{cab_low['ttr_s']:.3f}")
    claims.check(
        "Recovery is prompt: every crash scenario back above 70% of "
        "baseline within 0.5 simulated seconds, effective downtime "
        "under 0.45s",
        all(by[(p, s)]["recovered"] and by[(p, s)]["ttr_s"] <= 0.5
            and by[(p, s)]["downtime_s"] <= 0.45
            for p in ("woc", "cabinet") for s in crash_of),
        " ".join(f"{p}/{s}: ttr={by[(p, s)]['ttr_s']:.3f}s "
                 f"down={by[(p, s)]['downtime_s']:.3f}s"
                 for p in ("woc", "cabinet") for s in crash_of))
    woc_deg, cab_deg = by[("woc", "degrade_top")], by[("cabinet",
                                                       "degrade_top")]
    claims.check(
        "Degrading the top-weight node: WOC keeps a higher throughput "
        "floor than Cabinet (weights shift off the slow node; Cabinet's "
        "leader IS the slow node)",
        woc_deg["dip_frac"] >= cab_deg["dip_frac"],
        f"woc dip={woc_deg['dip_frac']:.2f} "
        f"cabinet dip={cab_deg['dip_frac']:.2f}")

    # -- critical-path attribution of the degradation window -----------------
    # split the recovery timeline at the fault boundaries and ask the
    # analyzer WHERE the extra latency went: inside [at, heal) the
    # decomposition should charge the throughput sag to quorum-straggler
    # waits on the degraded (top-weight) replica, not to queueing or the
    # link floor
    deg_node = resolve_node("top_weight", 5)
    inside = analyze_events(deg_trace, window=(at, heal))
    outside = analyze_events(deg_trace, window=(0.0, at))
    in_per_op = (inside.straggler_by_node.get(deg_node, 0.0)
                 / max(1, inside.analyzed))
    out_per_op = (outside.straggler_by_node.get(deg_node, 0.0)
                  / max(1, outside.analyzed))
    claims.check(
        "WOC degrade-top: critical-path analyzer attributes the in-window "
        "latency sag to quorum-straggler time on the degraded top-weight "
        "node (top straggler = degraded node; its per-op straggler charge "
        ">= 2x the pre-fault window)",
        inside.top_straggler() == deg_node
        and in_per_op >= 2 * out_per_op and in_per_op > 0.0,
        f"top_straggler={inside.top_straggler()} (degraded={deg_node}) "
        f"straggler/op in-window={in_per_op*1e3:.4f}ms "
        f"pre-fault={out_per_op*1e3:.4f}ms")
    critical_path = {"degraded_node": deg_node, "window_s": [at, heal],
                     "inside": inside.to_dict(),
                     "outside": outside.to_dict()}
    if trace:
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        write_trace(str(out / "TRACE_degrade_top_woc.json"), deg_trace)

    write_csv(out_dir, "fault_recovery", rows)
    write_json(out_dir, "BENCH_faults", {
        "bench": "fault_recovery",
        "quick": quick,
        "workload": "80/10/10, 20% reads, 4 clients",
        "fault_at_s": at,
        "scenarios": {f"{p}/{s}": by[(p, s)]
                      for p in ("woc", "cabinet")
                      for s in list(crash_of) + list(degrade)},
        "points": rows,
        "critical_path": critical_path,
        "claims": claims.lines,
    })
    return claims.lines


# benchmarks/run.py invokes ``mod.run(out_dir)`` on every suite module
run = run_bench  # noqa: F811 — intentional module-entrypoint alias
