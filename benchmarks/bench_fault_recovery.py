"""Fault recovery: WOC vs Cabinet under replica crashes (repro.faults).

The paper's heterogeneity story under failure: Cabinet serializes every
operation through its top-weighted leader, so its failure sensitivity is
ROLE-shaped — losing the leader is a full outage until re-election,
losing a low-weight follower barely registers (clients never talk to
it). WOC spreads coordination across all replicas, so its sensitivity is
CLIENT-shaped and uniform: any crash costs roughly the client-retry
constant regardless of the victim's weight, and no replica is
privileged. A degrade pair (top-weight node's network inflated 8x, then
healed) probes the same story without killing anyone: WOC's dynamic
weights shift quorums off the slow node, while Cabinet's leader IS the
slow node. Running the same degrade with ``Scenario.reassign`` enabled
adds the self-healing chapter: the health monitor confirms the slow
top-weight replica, the leader installs an epoch-stamped demotion, and
the commit rate climbs back to >= 80% of the pre-fault baseline while
the knob-off twin stays on the depressed floor.

Every scenario is a deterministic simulation: dips, time-to-recover and
effective downtime are exact functions of seed + schedule, so claims
here are hard checks, not wall-clock notes. Each run's history is
verified linearizable before any number is reported — an unverified
recovery curve is worthless.
"""

import pathlib

from benchmarks.common import Claims, write_csv, write_json

from repro.core.simulator import Workload
from repro.faults import Crash, Degrade, Recover, resolve_node
from repro.obs import analyze_events, write_trace
from repro.scenario import Observability, Reassign, Scenario, run_scenario
from repro.verify import (check_history_linearizable, downtime_by_phase,
                          effective_downtime, recovery_report,
                          throughput_timeline)

WORKLOAD = Workload(p_independent=0.8, p_common=0.1, p_hot=0.1,
                    n_hot_objects=4, reads_fraction=0.2)


def _scenario(proto: str, name: str, faults, fault_at: float,
              total_ops: int, claims: Claims, obs=None,
              reassign=None) -> tuple:
    art = run_scenario(
        Scenario(protocol=proto, total_ops=total_ops, batch_size=10,
                 n_clients=4, workload=WORKLOAD, faults=faults, seed=5,
                 obs=obs, reassign=reassign))
    r = art.result
    ok, why = check_history_linearizable(r.history)
    claims.check(f"{proto}/{name}: all ops commit, history linearizable",
                 ok and r.committed_ops == total_ops,
                 f"committed={r.committed_ops}/{total_ops} "
                 f"{'ok' if ok else why}")
    rep = recovery_report(r.history, fault_at,
                          weight_epochs=r.weight_epochs)
    return r, {"protocol": proto, "scenario": name,
            "ops": r.committed_ops, "makespan_s": round(r.makespan_s, 4),
            "tx_s": round(r.throughput_tx_s, 1),
            "baseline_tx_s": round(rep.baseline_tx_s, 1),
            "dip_tx_s": round(rep.dip_tx_s, 1),
            "dip_frac": round(rep.dip_frac, 4),
            "ttr_s": round(rep.time_to_recover_s, 4),
            "downtime_s": round(effective_downtime(r.history, fault_at), 4),
            "recovered": rep.recovered,
            "fast_frac": round(r.fast_path_frac, 4),
            "reassign": reassign is not None,
            "weight_installs": len(r.weight_epochs)}


def _window_rate(history, t0: float, t1: float, window: float = 0.05):
    """Best committed-op rate among the ``window``-sized slots whose
    start lies in ``[t0, t1)`` — "best" so the demote/restore probe
    oscillation late in a fault window cannot hide a recovered rate."""
    tl = throughput_timeline(history, window=window, t0=t0, t1=t1)
    return max((rate for _, rate in tl), default=0.0)


def run_bench(out_dir, quick: bool = False,
              trace: bool = False) -> list[str]:
    claims = Claims()
    total = 10_000 if quick else 30_000
    at = 0.05 if quick else 0.15
    rec = 0.2 if quick else 0.35
    heal = 0.25 if quick else 0.45

    crash_of = {"crash_low": (Crash(at, "low_weight"),
                              Recover(rec, "low_weight")),
                "crash_top": (Crash(at, "top_weight"),
                              Recover(rec, "top_weight"))}
    degrade = {"degrade_top": (Degrade(at, "top_weight", 8.0),
                               Degrade(heal, "top_weight", 1.0))}

    rows = []
    by = {}
    histories = {}
    deg_trace = None
    for proto in ("woc", "cabinet"):
        for name, faults in {**crash_of, **degrade}.items():
            # the recovery-timeline trace: op-level spans for the WOC
            # degrade run feed the critical-path attribution claim below
            # (recording is host-side only, so the numbers are identical
            # with tracing on)
            obs = (Observability(trace=True)
                   if (proto, name) == ("woc", "degrade_top") else None)
            r, row = _scenario(proto, name, faults, at, total, claims,
                               obs=obs)
            if obs is not None:
                deg_trace = r.trace
            rows.append(row)
            by[(proto, name)] = row
            histories[(proto, name)] = r.history

    # -- self-healing: the same degrade with weight reassignment on ----------
    r_ra, row_ra = _scenario("woc", "degrade_top_reassign",
                             degrade["degrade_top"], at, total, claims,
                             reassign=Reassign())
    rows.append(row_ra)
    by[("woc", "degrade_top_reassign")] = row_ra
    we = r_ra.weight_epochs
    claims.check(
        "WOC degrade-top with reassignment: the confirmed-slow top-weight "
        "replica is demoted to the ranking tail in weight epoch 1",
        bool(we) and we[0][1] == 1 and we[0][2][-1] == 0
        and at <= we[0][0] <= heal,
        f"installs={[(round(t, 3), e) for t, e, _, _ in we]}")
    # measure 0.1-0.2s past the onset: a fixed distance from the fault,
    # not from the heal, because the baseline's own per-object weight
    # EMAs eventually re-rank the degraded node too — reassignment's
    # payoff is recovering in one install backoff, not a different
    # asymptote
    pre_on = _window_rate(r_ra.history, max(0.0, at - 0.05), at)
    late_on = _window_rate(r_ra.history, at + 0.1, at + 0.2)
    off_hist = histories[("woc", "degrade_top")]
    pre_off = _window_rate(off_hist, max(0.0, at - 0.05), at)
    late_off = _window_rate(off_hist, at + 0.1, at + 0.2)
    claims.check(
        "Self-healing recovery: with reassignment the commit rate 0.1s "
        "after the onset is back to >= 80% of the pre-fault rate; with "
        "the knob off it is still below 70% (quorums pinned to the slow "
        "top-weight node until its per-object EMAs catch up much later)",
        late_on >= 0.8 * pre_on and late_off < 0.7 * pre_off,
        f"on={late_on:.0f}/{pre_on:.0f} ({late_on / pre_on:.1%}) "
        f"off={late_off:.0f}/{pre_off:.0f} ({late_off / pre_off:.1%})")
    detect_s, residual_s = downtime_by_phase(r_ra.history, at,
                                             r_ra.weight_epochs,
                                             horizon=heal - at)
    # the phases have very different lengths (detection is one backoff
    # floor, the installed view then rules the rest of the window), so
    # compare downtime *density*: seconds of effective downtime per
    # second of phase
    first_install = next(t for t, _, _, _ in r_ra.weight_epochs if t >= at)
    detect_win = max(first_install - at, 1e-9)
    residual_win = max(at + (heal - at) - first_install, 1e-9)
    claims.check(
        "Reassignment downtime split: the downtime density is paid "
        "detecting and confirming the slow replica (before the first "
        "install), not after the new weight view is in force",
        detect_s > 0.0 and residual_s / residual_win < detect_s / detect_win,
        f"detect={detect_s:.4f}s/{detect_win:.2f}s "
        f"({detect_s / detect_win:.0%}) residual={residual_s:.4f}s/"
        f"{residual_win:.2f}s ({residual_s / residual_win:.0%})")

    # -- the heterogeneity-under-failure story -------------------------------
    woc_low, woc_top = by[("woc", "crash_low")], by[("woc", "crash_top")]
    cab_low, cab_top = by[("cabinet", "crash_low")], by[("cabinet",
                                                         "crash_top")]
    claims.check(
        "Cabinet's crash sensitivity is role-shaped: leader (top-weight) "
        "crash is a hard outage, follower (low-weight) crash barely "
        "registers (>= 4x faster recovery)",
        cab_top["dip_frac"] == 0.0
        and cab_low["ttr_s"] * 4 <= cab_top["ttr_s"],
        f"ttr top={cab_top['ttr_s']:.3f}s low={cab_low['ttr_s']:.3f}s "
        f"dip top={cab_top['dip_frac']:.2f}")
    claims.check(
        "WOC has no privileged replica: top-weight and low-weight crash "
        "recoveries are within 2x of each other (Cabinet's differ >= 4x)",
        woc_low["ttr_s"] <= 2 * woc_top["ttr_s"]
        and woc_top["ttr_s"] <= 2 * woc_low["ttr_s"],
        f"woc ttr top={woc_top['ttr_s']:.3f}s low={woc_low['ttr_s']:.3f}s")
    claims.check(
        "Victim weight moves Cabinet's recovery time but not WOC's: "
        "cabinet ttr(top) > ttr(low); woc's two ttrs within two 50ms "
        "measurement windows of each other",
        cab_top["ttr_s"] > cab_low["ttr_s"]
        and abs(woc_top["ttr_s"] - woc_low["ttr_s"]) <= 0.1 + 1e-9,
        f"woc |{woc_top['ttr_s']:.3f}-{woc_low['ttr_s']:.3f}| "
        f"cabinet {cab_top['ttr_s']:.3f}>{cab_low['ttr_s']:.3f}")
    claims.check(
        "Recovery is prompt: every crash scenario back above 70% of "
        "baseline within 0.5 simulated seconds, effective downtime "
        "under 0.45s",
        all(by[(p, s)]["recovered"] and by[(p, s)]["ttr_s"] <= 0.5
            and by[(p, s)]["downtime_s"] <= 0.45
            for p in ("woc", "cabinet") for s in crash_of),
        " ".join(f"{p}/{s}: ttr={by[(p, s)]['ttr_s']:.3f}s "
                 f"down={by[(p, s)]['downtime_s']:.3f}s"
                 for p in ("woc", "cabinet") for s in crash_of))
    woc_deg, cab_deg = by[("woc", "degrade_top")], by[("cabinet",
                                                       "degrade_top")]
    claims.check(
        "Degrading the top-weight node: WOC keeps a higher throughput "
        "floor than Cabinet (weights shift off the slow node; Cabinet's "
        "leader IS the slow node)",
        woc_deg["dip_frac"] >= cab_deg["dip_frac"],
        f"woc dip={woc_deg['dip_frac']:.2f} "
        f"cabinet dip={cab_deg['dip_frac']:.2f}")

    # -- critical-path attribution of the degradation window -----------------
    # split the recovery timeline at the fault boundaries and ask the
    # analyzer WHERE the extra latency went: inside [at, heal) the
    # decomposition should charge the throughput sag to quorum-straggler
    # waits on the degraded (top-weight) replica, not to queueing or the
    # link floor
    deg_node = resolve_node("top_weight", 5)
    inside = analyze_events(deg_trace, window=(at, heal))
    outside = analyze_events(deg_trace, window=(0.0, at))
    in_per_op = (inside.straggler_by_node.get(deg_node, 0.0)
                 / max(1, inside.analyzed))
    out_per_op = (outside.straggler_by_node.get(deg_node, 0.0)
                  / max(1, outside.analyzed))
    claims.check(
        "WOC degrade-top: critical-path analyzer attributes the in-window "
        "latency sag to quorum-straggler time on the degraded top-weight "
        "node (top straggler = degraded node; its per-op straggler charge "
        ">= 2x the pre-fault window)",
        inside.top_straggler() == deg_node
        and in_per_op >= 2 * out_per_op and in_per_op > 0.0,
        f"top_straggler={inside.top_straggler()} (degraded={deg_node}) "
        f"straggler/op in-window={in_per_op*1e3:.4f}ms "
        f"pre-fault={out_per_op*1e3:.4f}ms")
    critical_path = {"degraded_node": deg_node, "window_s": [at, heal],
                     "inside": inside.to_dict(),
                     "outside": outside.to_dict()}
    if trace:
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        write_trace(str(out / "TRACE_degrade_top_woc.json"), deg_trace)

    write_csv(out_dir, "fault_recovery", rows)
    write_json(out_dir, "BENCH_faults", {
        "bench": "fault_recovery",
        "quick": quick,
        "workload": "80/10/10, 20% reads, 4 clients",
        "fault_at_s": at,
        "scenarios": {**{f"{p}/{s}": by[(p, s)]
                         for p in ("woc", "cabinet")
                         for s in list(crash_of) + list(degrade)},
                      "woc/degrade_top_reassign":
                          by[("woc", "degrade_top_reassign")]},
        "points": rows,
        "reassign": {
            "weight_epochs": [[round(t, 6), e, list(rk), b]
                              for t, e, rk, b in we],
            "pre_fault_tx_s": round(pre_on, 1),
            "late_window_tx_s": round(late_on, 1),
            "late_window_tx_s_no_reassign": round(late_off, 1),
            "recovery_frac": round(late_on / pre_on, 4),
            "recovery_frac_no_reassign": round(late_off / pre_off, 4),
            "detect_downtime_s": round(detect_s, 4),
            "residual_downtime_s": round(residual_s, 4),
        },
        "critical_path": critical_path,
        "claims": claims.lines,
    })
    return claims.lines


# benchmarks/run.py invokes ``mod.run(out_dir)`` on every suite module
run = run_bench  # noqa: F811 — intentional module-entrypoint alias
