"""Beyond-paper: sharded multi-group WOC scaling (src/repro/shard).

Sweeps G in {1, 2, 4, 8} consensus groups over a hash-partitioned object
space with per-group client populations, plus a cross-group locality
sweep and a WPaxos-style object-stealing ablation on the skewed
drifting-working-set workload.

Claims validated:
  * G=1 sharded == unsharded runner committed-op count (bit-for-bit,
    same seed) — the sharding layer is pay-for-what-you-use;
  * near-linear aggregate throughput for local workloads (G=4 >= 2.5x
    G=1; in this cost model the shared hot objects also shard their
    slow-path leaders, so the observed scaling is super-linear);
  * graceful degradation as cross-group traffic rises (p_local sweep);
  * object stealing migrates a drifting working set home: migrations
    occur and throughput beats the stealing-disabled ablation.
"""

from benchmarks.common import (Claims, run_point, sharded_point, write_csv,
                               write_json)

from repro.core.simulator import CostModel
from repro.scenario import Sharding

GROUPS = [1, 2, 4, 8]
BASE_OPS = 12_000        # per group, so per-group load is constant
P_LOCAL = [1.0, 0.9, 0.7, 0.5]


def run_bench(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    base_ops = 4_000 if quick else BASE_OPS
    rows = []

    # -- uniform-locality group sweep --------------------------------------
    by_g = {}
    for g in GROUPS:
        r = sharded_point(Sharding(n_groups=g, locality="uniform"),
                          total_ops=base_ops * g, batch_size=10, seed=3)
        rows.append(r)
        by_g[g] = r["tx_s"]

    flat = run_point(protocol="woc", total_ops=base_ops, batch_size=10,
                     seed=3)
    claims.check("Shard G=1 == unsharded committed ops (same seed)",
                 by_g and rows[0]["ops"] == flat["ops"],
                 f"sharded={rows[0]['ops']} flat={flat['ops']}")
    claims.check("Shard G=4 uniform >= 2.5x G=1 aggregate throughput",
                 by_g[4] >= 2.5 * by_g[1],
                 f"G4={by_g[4]:.0f} G1={by_g[1]:.0f} "
                 f"ratio={by_g[4] / by_g[1]:.2f}")
    claims.check("Shard G=8 uniform >= 5x G=1 (near-linear)",
                 by_g[8] >= 5.0 * by_g[1],
                 f"G8={by_g[8]:.0f} ratio={by_g[8] / by_g[1]:.2f}")

    # -- graceful degradation: cross-group traffic sweep at G=4 -------------
    by_p = {}
    for p in P_LOCAL:
        r = sharded_point(Sharding(n_groups=4, locality="mixed", p_local=p,
                                   steal_threshold=0),
                          total_ops=base_ops * 4, batch_size=10, seed=3)
        rows.append(r)
        by_p[p] = r["tx_s"]
    claims.check("Shard degradation is graceful: G=4 at 50% remote "
                 "traffic keeps >= 35% of fully-local throughput",
                 by_p[0.5] >= 0.35 * by_p[1.0],
                 f"{ {p: round(v) for p, v in by_p.items()} }")

    # -- object stealing on the drifting skewed workload --------------------
    # WAN-flavored remote penalty (6 ms one-way to a non-home group): the
    # regime WPaxos targets, where serving a client from a remote region
    # caps its open-loop pipeline on RTT
    wan = CostModel(net_remote_client=6e-3)
    drift = dict(locality="drift", working_set=12, p_working=0.85,
                 drift_every=300)
    steal = sharded_point(Sharding(n_groups=4, steal_threshold=3, **drift),
                          total_ops=base_ops * 4, batch_size=10, seed=7,
                          costs=wan)
    frozen = sharded_point(Sharding(n_groups=4, steal_threshold=0, **drift),
                           total_ops=base_ops * 4, batch_size=10, seed=7,
                           costs=wan)
    rows += [steal, frozen]
    claims.check("Object stealing migrates the working set "
                 "(migrations > 0, remote fraction below ablation)",
                 steal["migrations"] > 0
                 and steal["remote_frac"] < frozen["remote_frac"],
                 f"migrations={steal['migrations']} "
                 f"remote {steal['remote_frac']:.3f} vs "
                 f"{frozen['remote_frac']:.3f}")
    claims.check("Object stealing beats static placement on the "
                 "drifting WAN workload (>= 1.3x throughput, lower p50)",
                 steal["tx_s"] >= 1.3 * frozen["tx_s"]
                 and steal["p50_ms"] < frozen["p50_ms"],
                 f"steal={steal['tx_s']:.0f} frozen={frozen['tx_s']:.0f} "
                 f"ratio={steal['tx_s'] / max(frozen['tx_s'], 1e-9):.2f} "
                 f"p50 {steal['p50_ms']:.2f} vs {frozen['p50_ms']:.2f} ms")

    write_csv(out_dir, "shard_scaling", rows)
    write_json(out_dir, "BENCH_shard", {
        "bench": "shard_scaling",
        "uniform_sweep": {str(g): by_g[g] for g in GROUPS},
        "speedup_vs_g1": {str(g): round(by_g[g] / by_g[1], 3)
                          for g in GROUPS},
        "p_local_sweep": {str(p): by_p[p] for p in P_LOCAL},
        "stealing": {"enabled": steal, "disabled": frozen},
        "points": rows,
        "claims": claims.lines,
    })
    return claims.lines


# benchmarks/run.py invokes ``mod.run(out_dir)`` on every suite module
run = run_bench  # noqa: F811 — intentional module-entrypoint alias
