"""Parallel sharded simulation benchmark (PR 3 tentpole): wall-clock
scaling of per-group event engines vs the single-heap serial oracle.

The reference scenario is the G=8 uniform-locality point of the shard
scaling sweep — the exact configuration the serial engine is slowest on
and the regime the paper's >70%-independent claim targets. Measurement
uses the shared paired interleaved A/B harness (benchmarks.common): the
serial and parallel runs alternate so container CPU-share noise hits
both sides, and the speedup claim reads the ratio of medians.

Two claims ride along that are NOT machine-dependent:

  * serial (workers=1) and parallel (workers>=2) runs of the reference
    are **bit-identical** on every non-telemetry ShardedRunResult field
    (the tentpole's determinism contract, also pinned per-locality by
    tests/test_parallel.py);
  * barrier/idle telemetry is populated, so lookahead tuning is
    observable rather than guessed.

The >=2x wall-clock claim is only *checked* on machines with >= 4 cores
(the acceptance environment); on smaller containers the measured ratio
is recorded as an informational note — 2 workers on 2 busy cores cannot
reach 2x by construction.
"""

from __future__ import annotations

import dataclasses
import os

from benchmarks.common import Claims, calibration_score, paired_ab, write_json

from repro.scenario import Scenario, Sharding, run_scenario
from repro.shard import lookahead_of, non_telemetry_metrics as _metrics

REFERENCE = dict(protocol="woc", n_groups=8, n_replicas_per_group=5,
                 n_clients_per_group=2, batch_size=10, locality="uniform",
                 seed=3)


def _scenario(cfg: dict, workers: int) -> Scenario:
    return Scenario(
        protocol=cfg["protocol"], n_replicas=cfg["n_replicas_per_group"],
        n_clients=cfg["n_clients_per_group"], batch_size=cfg["batch_size"],
        total_ops=cfg["total_ops"], seed=cfg["seed"],
        sharding=Sharding(n_groups=cfg["n_groups"],
                          locality=cfg["locality"], workers=workers))
BASE_OPS = 12_000          # per group (matches bench_shard_scaling)
QUICK_OPS = 3_000
SPEEDUP_TARGET = 2.0       # on a >= 4-core runner
MIN_CORES_FOR_CLAIM = 4


def run_bench(out_dir, quick: bool = False, jobs: int = 0) -> list[str]:
    claims = Claims()
    cores = os.cpu_count() or 1
    ops_per_group = QUICK_OPS if quick else BASE_OPS
    repeats = 2 if quick else 3
    cfg = dict(REFERENCE, total_ops=ops_per_group * REFERENCE["n_groups"])
    workers = jobs if jobs > 0 else min(cfg["n_groups"], cores)

    serial_sc = _scenario(cfg, workers=1)
    parallel_sc = _scenario(cfg, workers=workers)

    # determinism first (also warms both paths for the A/B below)
    serial = run_scenario(serial_sc).result
    parallel = run_scenario(parallel_sc).result
    identical = _metrics(serial) == _metrics(parallel)
    claims.check(
        "parallel (workers>=2) bit-identical to serial oracle on the "
        f"G={cfg['n_groups']} reference",
        identical,
        f"workers={parallel.workers} committed={parallel.committed_ops} "
        f"tx_s={parallel.throughput_tx_s:.0f} "
        + ("all non-telemetry fields equal" if identical
           else "FIELDS DIVERGE"))
    claims.check(
        "per-engine telemetry populated (barriers, idle-wait, engines)",
        parallel.barriers > 0 and len(parallel.per_engine)
        == cfg["n_groups"],
        f"barriers={parallel.barriers} "
        f"idle_wait_frac={parallel.idle_wait_frac:.3f} "
        f"engines={len(parallel.per_engine)}")

    # paired interleaved A/B wall clock (shared harness; no warmup run —
    # the determinism pass above already warmed both paths)
    probe = calibration_score()
    ab = paired_ab(lambda: run_scenario(serial_sc),
                   lambda: run_scenario(parallel_sc),
                   repeats=repeats, warmup=False)
    headline = (f"parallel >= {SPEEDUP_TARGET:.0f}x serial wall-clock on "
                f"the G={cfg['n_groups']} uniform reference")
    detail = (f"serial median {ab['a_median_s']:.2f}s vs parallel "
              f"{ab['b_median_s']:.2f}s = {ab['ratio']:.2f}x "
              f"({workers} workers, {cores} cores)")
    if quick or cores < MIN_CORES_FOR_CLAIM:
        claims.note(
            headline + f" [informational: {cores} cores"
            + (", quick" if quick else "") + "]", detail)
    else:
        claims.check(headline, ab["ratio"] >= SPEEDUP_TARGET, detail)

    write_json(out_dir, "BENCH_parallel", {
        "bench": "parallel_shard",
        "scenario": dict(cfg),
        "quick": quick,
        "repeats": repeats,
        "workers": workers,
        "cores": cores,
        "lookahead_s": lookahead_of(serial_sc.costs),
        "paired_ab": ab,
        "speedup": ab["ratio"],
        "calibration_probe": round(probe, 1),
        "serial": {
            "committed_ops": serial.committed_ops,
            "throughput_tx_s": round(serial.throughput_tx_s, 1),
            "events": serial.events,
            "wall_s": round(serial.wall_s, 3),
        },
        "parallel": {
            "committed_ops": parallel.committed_ops,
            "throughput_tx_s": round(parallel.throughput_tx_s, 1),
            "events": parallel.events,
            "barriers": parallel.barriers,
            "idle_wait_frac": round(parallel.idle_wait_frac, 4),
            "per_engine": [dataclasses.asdict(e)
                           for e in parallel.per_engine],
        },
        "bit_identical": identical,
        "claims": claims.lines,
    })
    return claims.lines


# benchmarks/run.py invokes ``mod.run(out_dir, quick=..., jobs=...)``
run = run_bench  # noqa: F811 — intentional module-entrypoint alias
