"""Engine micro-benchmark (PR 2 tentpole): raw simulator throughput.

Every paper claim this repo validates is measured on the discrete-event
simulator, so its events/sec is the hard ceiling on how many scenarios,
seeds, and cluster sizes each PR can afford. This suite pins the engine's
speed on the fixed 9-replica reference scenario (the largest cluster in
the paper's §5 sweeps, 4 open-loop clients, batch 100 — the throughput
configuration that dominates sweep wall time; the paper-default batch-10
configuration rides along as a secondary point) and records both in
``BENCH_engine.json`` so cross-PR regressions are visible.

Measurement notes:

  * events/sec comes from ``Simulation.wall_s`` (perf_counter time inside
    ``Simulation.run`` only — no setup, no metric collection), best of
    ``repeats`` runs to shed scheduler noise; the container's CPU share
    fluctuates, so single samples are untrustworthy.
  * ``BASELINE_*`` are the pre-overhaul engine (commit b40ecf8) measured
    at PR time with this exact scenario and methodology, in the same
    session as a pure-Python **calibration probe**
    (:func:`calibration_score`). The container's CPU share fluctuates
    ~1.5x minute-to-minute and CI hardware differs entirely, so at claim
    time the probe runs again and the baseline is scaled by the measured
    machine-speed ratio — the comparison is approximately
    machine-independent instead of hostage to scheduler phase. Treat a
    full-mode claim MISS as "re-baseline on this machine" only after a
    repeat run also misses.
  * the speedup claim uses events / *total* wall (setup included) on
    both sides — the pre-PR engine had no engine-only wall telemetry, so
    like must be compared with like; the engine-only ``events_per_sec``
    is recorded alongside as telemetry.
  * determinism is also asserted here (same seed => identical committed
    trace), because a fast engine that drifts is worthless for baselines.
"""

from __future__ import annotations

import time

from benchmarks.common import Claims, calibration_score, write_json

from repro.scenario import Scenario, run_scenario

# pre-PR engine (commit b40ecf8) on the reference scenario: best-of-4,
# events / total wall, measured in one session together with the
# calibration probe below — see module docstring before editing. The
# reference is the throughput configuration (batch 100): the §5-style
# sweeps' wall time is dominated by their large-batch points, which is
# exactly the cost the overhaul targets. The paper-default batch-10
# configuration is recorded alongside as a secondary point.
BASELINE_EVENTS_PER_SEC = 4_208.0
SECONDARY_BASELINE_EVENTS_PER_SEC = 32_303.0     # batch=10, 10k ops
BASELINE_PROBE_SCORE = 2_850_000.0               # calibration_score() then
SPEEDUP_TARGET = 3.0

# calibration_score lives in benchmarks.common (shared with the
# bench_parallel_shard suite); re-exported above for baseline provenance.

REFERENCE = dict(protocol="woc", n_replicas=9, n_clients=4, batch_size=100,
                 t_fail=2, seed=0)
SECONDARY = dict(protocol="woc", n_replicas=9, n_clients=4, batch_size=10,
                 t_fail=2, seed=0)


def _reference_cfg(total_ops: int) -> Scenario:
    return Scenario(total_ops=total_ops, **REFERENCE)


def _trace_sig(art) -> tuple:
    """Determinism signature: the committed-op trace, order-independent of
    wall clock (no telemetry fields)."""
    ops = sorted((op.op_id, op.obj, op.commit_time, op.path)
                 for c in art.clients for op in c.ops)
    return (len(ops), hash(tuple(ops)),
            art.result.makespan_s, art.result.committed_ops)


def _measure(cfg_kw: dict, total: int, repeats: int) -> dict:
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        art = run_scenario(Scenario(total_ops=total, **cfg_kw))
        wall = time.perf_counter() - t0
        r = art.result
        point = {
            "batch_size": cfg_kw["batch_size"],
            "total_ops": total,
            "events": r.events,
            "messages": r.messages,
            "events_per_sec": round(r.events_per_sec, 1),
            "events_per_sec_total_wall": round(r.events / wall, 1),
            "engine_wall_s": round(r.wall_s, 4),
            "total_wall_s": round(wall, 4),
            "heap_peak": r.heap_peak,
            "collapsed_events": art.sim.stats_collapsed,
            "committed_ops": r.committed_ops,
            "throughput_tx_s": round(r.throughput_tx_s, 1),
            "fast_path_frac": round(r.fast_path_frac, 4),
        }
        if best is None or (point["events_per_sec_total_wall"]
                            > best["events_per_sec_total_wall"]):
            best = point
    return best


def run_bench(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    total = 10_000 if quick else 40_000
    repeats = 2 if quick else 4

    run_scenario(_reference_cfg(2_000))    # warm imports/allocator
    probe = calibration_score()
    scale = probe / BASELINE_PROBE_SCORE
    best = _measure(REFERENCE, total, repeats)
    secondary = _measure(SECONDARY, total // 4, repeats)

    # determinism spot-check rides along: two fresh runs, same seed
    sig_a = _trace_sig(run_scenario(_reference_cfg(2_000)))
    sig_b = _trace_sig(run_scenario(_reference_cfg(2_000)))

    evs = best["events_per_sec_total_wall"]
    speedup = evs / (BASELINE_EVENTS_PER_SEC * scale)
    evs2 = secondary["events_per_sec_total_wall"]
    speedup2 = evs2 / (SECONDARY_BASELINE_EVENTS_PER_SEC * scale)
    headline = (f"engine >= {SPEEDUP_TARGET:.0f}x pre-PR events/sec on "
                f"the 9-replica reference scenario")
    detail = (f"{evs:,.0f} ev/s vs machine-scaled baseline "
              f"{BASELINE_EVENTS_PER_SEC * scale:,.0f} "
              f"({speedup:.2f}x; probe scale {scale:.2f})")
    if quick:
        # CI/laptop hardware differs from the machine the baseline was
        # recorded on: report, don't fail
        claims.note(headline + " [quick: informational]", detail)
    else:
        claims.check(headline, speedup >= SPEEDUP_TARGET, detail)
    claims.note("secondary point: batch=10 paper-default configuration",
                f"{evs2:,.0f} ev/s vs machine-scaled baseline "
                f"{SECONDARY_BASELINE_EVENTS_PER_SEC * scale:,.0f} "
                f"({speedup2:.2f}x)")
    claims.check("same-seed determinism (committed trace + makespan)",
                 sig_a == sig_b, f"sig={sig_a[:2]}")
    claims.check("all reference ops committed",
                 best["committed_ops"] == total,
                 f"{best['committed_ops']}/{total}")

    write_json(out_dir, "BENCH_engine", {
        "bench": "engine",
        "scenario": {**REFERENCE, "total_ops": total},
        "quick": quick,
        "repeats": repeats,
        "best": best,
        "secondary": secondary,
        "baseline_events_per_sec": BASELINE_EVENTS_PER_SEC,
        "secondary_baseline_events_per_sec":
            SECONDARY_BASELINE_EVENTS_PER_SEC,
        "calibration": {"probe_score": round(probe, 1),
                        "baseline_probe_score": BASELINE_PROBE_SCORE,
                        "scale": round(scale, 4)},
        "speedup_vs_baseline": round(speedup, 3),
        "secondary_speedup_vs_baseline": round(speedup2, 3),
        "claims": claims.lines,
    })
    return claims.lines


# benchmarks/run.py invokes ``mod.run(out_dir, quick=...)`` on every suite
run = run_bench  # noqa: F811 — intentional module-entrypoint alias
