"""Quorum-commit compute micro-benchmark (§5.4's "quorum computation").

Compares: (a) per-op Python/numpy loop (what a Go implementation does per
message), (b) vectorized jnp batch (the library path), (c) the Pallas
kernel in interpret mode (correctness proxy; the TPU path is the target).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Claims, write_csv
from repro.core.quorum import quorum_commit
from repro.kernels.quorum_commit import quorum_commit_pallas


def _python_loop(arrivals, weights):
    out = []
    for t, w in zip(arrivals, weights):
        order = np.argsort(t)
        acc, hit = 0.0, np.inf
        thresh = w.sum() / 2
        for k, i in enumerate(order):
            if not np.isfinite(t[i]):
                break
            acc += w[i]
            if acc > thresh:
                hit = t[i]
                break
        out.append(hit)
    return np.array(out)


def run(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    rng = np.random.default_rng(0)
    rows = []
    shapes = [(1024, 8), (8192, 8), (8192, 32), (65536, 16)]
    if quick:
        shapes = shapes[:2]
    for ops, n in shapes:
        arrivals = rng.uniform(0, 10, (ops, n)).astype(np.float32)
        weights = rng.uniform(0.5, 8.0, (ops, n)).astype(np.float32)

        t0 = time.perf_counter()
        ref = _python_loop(arrivals, weights)
        t_py = time.perf_counter() - t0

        a, w = jnp.asarray(arrivals), jnp.asarray(weights)
        f = jax.jit(lambda a, w: quorum_commit(a, w).commit_time)
        f(a, w).block_until_ready()
        t0 = time.perf_counter()
        got = f(a, w)
        got.block_until_ready()
        t_jnp = time.perf_counter() - t0

        ok = np.allclose(np.asarray(got), ref, rtol=1e-5)
        rows.append({"ops": ops, "n": n,
                     "python_us_per_op": round(t_py / ops * 1e6, 3),
                     "jnp_us_per_op": round(t_jnp / ops * 1e6, 3),
                     "speedup": round(t_py / max(t_jnp, 1e-9), 1),
                     "allclose": ok})
    write_csv(out_dir, "quorum_kernel_microbench", rows)

    # interpret-mode correctness of the Pallas kernel at bench shapes
    a = rng.uniform(0, 10, (512, 16)).astype(np.float32)
    w = rng.uniform(0.5, 8.0, (512, 16)).astype(np.float32)
    ct, _, cm, _ = quorum_commit_pallas(jnp.asarray(a), jnp.asarray(w),
                                        interpret=True)
    res = quorum_commit(jnp.asarray(a), jnp.asarray(w))
    claims.check("Pallas quorum kernel == jnp oracle",
                 bool(jnp.all(res.committed == cm))
                 and np.allclose(np.asarray(ct)[np.asarray(cm)],
                                 np.asarray(res.commit_time)[np.asarray(cm)]),
                 "interpret-mode allclose at (512,16)")
    claims.check("vectorized quorum math beats per-op loop",
                 all(r["speedup"] > 3 for r in rows),
                 f"speedups {[r['speedup'] for r in rows]}")
    return claims.lines
