"""Workload-axis benchmark (Scenario API tentpole): the paper's headline
claim is workload-shaped — WOC wins when >70% of objects are independent
and degrades gracefully as contention rises — but the §5 figures only
probe it on the discrete 90/5/5 knobs. This suite sweeps contention on a
*continuous* axis (Zipf skew over a 64Ki shared object space) across
woc/cabinet/epaxos, locating the crossover where WOC's advantage
evaporates, plus three scenario-API exclusives: a read-fraction sweep
(restricted by registry read-path metadata), bursty open-loop arrivals,
and the unsharded drifting-hotspot generator.

Every claim here is exact: all numbers are deterministic functions of
seed + Scenario, so quick mode checks the same claims on smaller sweeps
(CI runs ``--quick --only workloads``).

The crossover bracketing: rather than asserting one magic θ*, the suite
checks that every sweep point with a majority-independent fast path
(fast_frac >= 0.6) keeps a >= 1.5x advantage and every point with a
minority fast path (< 0.4) has none (<= 1.25x) — the paper's ~70%
independence threshold falls inside that bracket, and the interpolated
θ* is recorded in ``BENCH_workloads.json`` for cross-PR tracking.
"""

from __future__ import annotations

from collections import defaultdict

from benchmarks.common import Claims, write_csv, write_json

from repro.core.simulator import Workload
from repro.scenario import (BurstyWorkload, HotspotDriftWorkload, Leases,
                            Scenario, ZipfWorkload, protocol_info,
                            protocols_with, run_scenario)

THETAS = [0.0, 0.4, 0.8, 1.0, 1.2, 1.5, 2.0, 2.5]
READ_FRACTIONS = [0.0, 0.25, 0.5, 0.75]
N_OBJECTS = 1 << 16
ZIPF_PROTOS = ("woc", "cabinet", "epaxos")
ADV_RATIO = 1.25          # below this the advantage is considered gone

# -- leased local reads (repro.core.leases) ---------------------------------
# The lease points run at a FIXED op count even in quick mode: the
# adaptive grant policy needs several lease durations of per-object
# read/write history before it starts serving locally, so a short run
# measures mostly the pre-grant transient and under-reports the win.
# 12k ops at these settings is a couple of wall-seconds per point.
LEASE_TOTAL = 12_000
LEASE_READ_FRACTIONS = [0.0, 0.25, 0.5, 0.75, 0.9]
LEASE_THETAS = [0.0, 1.0, 2.0, 3.0]      # write-churn axis at rf=0.9
LEASE_RF_QUICK = [0.0, 0.75, 0.9]
LEASE_THETAS_QUICK = [0.0, 2.0]
MONO_TOL = 0.97   # "monotone": each point >= 97% of the previous one —
                  # the adaptive policy bounds mid-sweep grant-ratchet
                  # noise to a few percent, it does not eliminate it


def _independent_frac(art) -> float:
    """Fraction of ops whose object was touched by a single client over
    the whole run (the direct 'independent objects' measure)."""
    owners = defaultdict(set)
    for c in art.clients:
        for op in c.ops:
            owners[op.obj].add(op.client)
    ops = [op for c in art.clients for op in c.ops]
    return sum(1 for op in ops if len(owners[op.obj]) == 1) / len(ops)


def _point(sc: Scenario) -> tuple:
    art = run_scenario(sc)
    r = art.result
    return art, {"protocol": r.protocol, "ops": r.committed_ops,
                 "tx_s": round(r.throughput_tx_s, 1),
                 "p50_ms": round(r.latency_p50_ms, 4),
                 "p99_ms": round(r.latency_p99_ms, 4),
                 "fast_frac": round(r.fast_path_frac, 4),
                 "read_local_frac": round(r.read_local_frac, 4)}


def _cross_theta(ratios: dict) -> float:
    """Linear interpolation of the θ where woc/cabinet falls to
    ADV_RATIO (inf if it never does)."""
    prev_t, prev_r = None, None
    for t in THETAS:
        r = ratios[t]
        if r <= ADV_RATIO and prev_t is not None:
            return prev_t + (prev_r - ADV_RATIO) / (prev_r - r) \
                * (t - prev_t)
        prev_t, prev_r = t, r
    return float("inf")


def run_bench(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    total = 4_000 if quick else 12_000
    rows = []

    # -- Zipf skew sweep (the continuous contention axis) -------------------
    by = {}
    indep = {}
    for theta in THETAS:
        w = ZipfWorkload(n_objects=N_OBJECTS, theta=theta)
        for proto in ZIPF_PROTOS:
            art, row = _point(Scenario(protocol=proto, total_ops=total,
                                       batch_size=10, workload=w, seed=1))
            row.update(sweep="zipf", theta=theta,
                       independence_index=round(w.independence_index(), 5))
            rows.append(row)
            by[(proto, theta)] = row
            if proto == "woc":
                indep[theta] = round(_independent_frac(art), 4)
                row["independent_frac"] = indep[theta]

    ratios = {t: by[("woc", t)]["tx_s"] / by[("cabinet", t)]["tx_s"]
              for t in THETAS}
    theta_star = _cross_theta(ratios)

    claims.check("Zipf uniform end (θ=0): WOC >= 3x Cabinet with >= 95% "
                 "fast-path commits",
                 ratios[0.0] >= 3.0
                 and by[("woc", 0.0)]["fast_frac"] >= 0.95,
                 f"ratio={ratios[0.0]:.2f} "
                 f"fast={by[('woc', 0.0)]['fast_frac']:.3f}")
    fast = [by[("woc", t)]["fast_frac"] for t in THETAS]
    claims.check("WOC fast-path fraction monotone non-increasing in θ",
                 all(fast[i] >= fast[i + 1] - 0.02
                     for i in range(len(fast) - 1)),
                 f"fast curve {fast}")
    cab = [by[("cabinet", t)]["tx_s"] for t in THETAS]
    claims.check("Cabinet skew-insensitive (leader bound at every θ)",
                 max(cab) / min(cab) < 1.1,
                 f"range {min(cab):.0f}-{max(cab):.0f}")
    claims.check("crossover located on the continuous axis: advantage "
                 f"gone (<= {ADV_RATIO}x) by θ=1.5, θ* interpolable",
                 ratios[1.5] <= ADV_RATIO and 0.8 <= theta_star <= 1.8,
                 f"θ*={theta_star:.2f} "
                 f"ratios={ {t: round(r, 2) for t, r in ratios.items()} }")
    hi = [t for t in THETAS if by[("woc", t)]["fast_frac"] >= 0.6]
    lo = [t for t in THETAS if by[("woc", t)]["fast_frac"] < 0.4]
    claims.check("advantage needs a majority-independent workload: "
                 ">= 1.5x wherever fast-path >= 0.6, none (<= 1.25x) "
                 "wherever fast-path < 0.4 (brackets the paper's ~70% "
                 "independence threshold)",
                 hi and lo and all(ratios[t] >= 1.5 for t in hi)
                 and all(ratios[t] <= ADV_RATIO for t in lo),
                 f"hi θ={hi} lo θ={lo} "
                 f"indep_frac@lo={ {t: indep[t] for t in lo} }")
    claims.check("epaxos (write-only per registry read metadata) commits "
                 "every op at every θ",
                 all(by[("epaxos", t)]["ops"] == total for t in THETAS),
                 f"{len(THETAS)} θ points x {total} ops")

    # -- read-fraction sweep (registry-gated) -------------------------------
    read_protos = protocols_with(reads="linearizable")
    read_rows = {}
    for proto in read_protos:
        for rf in READ_FRACTIONS:
            _, row = _point(Scenario(
                protocol=proto, total_ops=total, batch_size=10,
                workload=Workload(reads_fraction=rf), seed=1))
            row.update(sweep="reads", reads_fraction=rf)
            rows.append(row)
            read_rows[(proto, rf)] = row
    assert "epaxos" not in read_protos \
        and protocol_info("epaxos").reads == "unverified"
    claims.check("read sweep commits every op for every verified-read "
                 f"protocol {read_protos}",
                 all(read_rows[(p, rf)]["ops"] == total
                     for p in read_protos for rf in READ_FRACTIONS),
                 f"{len(read_protos)}x{len(READ_FRACTIONS)} points")
    claims.check("reads ride the consensus path at write cost: per-"
                 "protocol throughput identical at every read fraction "
                 "(kind only changes the applied value, never timing)",
                 all(len({read_rows[(p, rf)]["tx_s"]
                          for rf in READ_FRACTIONS}) == 1
                     for p in read_protos),
                 f"woc tx={read_rows[('woc', 0.0)]['tx_s']} at all "
                 f"fractions")

    # -- leased local reads: read-fraction sweep (lease_reads-gated) --------
    lease_protos = protocols_with(lease_reads=True)
    assert "woc" in lease_protos and "epaxos" not in lease_protos
    lease_rfs = LEASE_RF_QUICK if quick else LEASE_READ_FRACTIONS
    lease_thetas = LEASE_THETAS_QUICK if quick else LEASE_THETAS

    def _lease_point(rf, theta, on):
        _, row = _point(Scenario(
            protocol="woc", n_replicas=5, n_clients=4, batch_size=4,
            total_ops=LEASE_TOTAL, seed=3,
            workload=ZipfWorkload(n_objects=64, theta=theta,
                                  reads_fraction=rf),
            leases=Leases(grant_after_reads=1) if on else None))
        row.update(sweep="leases", reads_fraction=rf, theta=theta,
                   leases="on" if on else "off")
        rows.append(row)
        return row

    lease_rows = {rf: _lease_point(rf, 0.0, True) for rf in lease_rfs}
    tx = [lease_rows[rf]["tx_s"] for rf in lease_rfs]
    local = [lease_rows[rf]["read_local_frac"] for rf in lease_rfs]
    claims.check("leased reads: every op still commits at every read "
                 "fraction",
                 all(lease_rows[rf]["ops"] == LEASE_TOTAL
                     for rf in lease_rfs),
                 f"{len(lease_rfs)} points x {LEASE_TOTAL} ops")
    claims.check("leased reads turn the flat read line into a rising "
                 "one: throughput monotone in read fraction (within the "
                 f"{100 - MONO_TOL * 100:.0f}% grant-noise floor)",
                 all(tx[i + 1] >= MONO_TOL * tx[i]
                     for i in range(len(tx) - 1)),
                 f"tx {tx} at rf {lease_rfs}")
    claims.check("leased reads: >= 2x throughput at 90% reads vs 0% "
                 "(θ=0), with a majority of reads served locally",
                 tx[-1] >= 2.0 * tx[0] and local[-1] >= 0.5,
                 f"ratio={tx[-1] / tx[0]:.2f} local={local[-1]:.3f}")
    claims.check("read_local_frac rises with read fraction (the adaptive "
                 "policy leases read-hot objects only)",
                 all(local[i + 1] >= local[i] - 0.02
                     for i in range(len(local) - 1)),
                 f"local {local}")

    # -- leased local reads: write-churn axis (lease value crossover) -------
    churn = {}
    for theta in lease_thetas:
        on = (lease_rows[0.9] if theta == 0.0 and 0.9 in lease_rows
              else _lease_point(0.9, theta, True))
        off = _lease_point(0.9, theta, False)
        churn[theta] = (on, off)
    cr = {t: churn[t][0]["tx_s"] / churn[t][1]["tx_s"]
          for t in lease_thetas}
    claims.check("lease-churn crossover: >= 2x win at θ=0 decaying to "
                 "parity (<= 1.15x) by θ=2 as write-hot heads stop "
                 "being leased",
                 cr[lease_thetas[0]] >= 2.0 and cr[2.0] <= 1.15,
                 f"on/off ratios { {t: round(r, 3) for t, r in cr.items()} }")
    claims.check("bounded downside: leases never cost more than 5% at "
                 "any churn point (revocation tax capped by the adaptive "
                 "policy + piggybacked revocation)",
                 min(cr.values()) >= 0.95,
                 f"min ratio {min(cr.values()):.3f}")
    churn_local = [churn[t][0]["read_local_frac"] for t in lease_thetas]
    claims.check("local-serve fraction decays with churn (θ up -> "
                 "write-hot heads dominate -> fewer live leases)",
                 all(churn_local[i + 1] <= churn_local[i] + 0.02
                     for i in range(len(churn_local) - 1)),
                 f"local {churn_local} at θ {lease_thetas}")

    # -- bursty open-loop arrivals ------------------------------------------
    base = Scenario(protocol="woc", total_ops=total, batch_size=10, seed=2)
    bursty_sc = Scenario(protocol="woc", total_ops=total, batch_size=10,
                         seed=2, workload=BurstyWorkload(burst_batches=20,
                                                         gap_s=0.01))
    steady_art, steady = _point(base)
    bursty_art, bursty = _point(bursty_sc)
    steady.update(sweep="arrivals", shape="steady")
    bursty.update(sweep="arrivals", shape="bursty")
    rows += [steady, bursty]
    stream = lambda art: sorted((o.op_id, o.obj, o.kind)  # noqa: E731
                                for c in art.clients for o in c.ops)
    claims.check("bursty arrivals draw the identical op stream (arrival "
                 "shaping never re-keys the workload) yet stretch "
                 "makespan / cut throughput",
                 stream(steady_art) == stream(bursty_art)
                 and bursty["ops"] == steady["ops"]
                 and bursty["tx_s"] < steady["tx_s"],
                 f"tx {bursty['tx_s']:.0f} vs {steady['tx_s']:.0f}, "
                 f"identical {total}-op stream")
    claims.check("burst lulls drain queues: bursty p50 <= steady p50",
                 bursty["p50_ms"] <= steady["p50_ms"] + 1e-9,
                 f"p50 {bursty['p50_ms']:.3f} vs {steady['p50_ms']:.3f} ms")

    # -- drifting hotspot (unsharded drift analog) --------------------------
    _, drift = _point(Scenario(
        protocol="woc", total_ops=total, batch_size=10, seed=2,
        workload=HotspotDriftWorkload(n_hot=8, p_hot=0.5,
                                      drift_every=total // 8)))
    drift.update(sweep="drift")
    rows.append(drift)
    claims.check("drifting hotspot: all ops commit and the fast path "
                 "tracks the non-hot share (p_hot=0.5 -> fast within "
                 "0.35-0.65)",
                 drift["ops"] == total
                 and 0.35 <= drift["fast_frac"] <= 0.65,
                 f"fast={drift['fast_frac']:.3f} tx={drift['tx_s']:.0f}")

    write_csv(out_dir, "workload_sweeps", rows)
    write_json(out_dir, "BENCH_workloads", {
        "bench": "workloads",
        "quick": quick,
        "total_ops": total,
        "zipf": {"n_objects": N_OBJECTS,
                 "thetas": THETAS,
                 "woc_cabinet_ratio": {str(t): round(ratios[t], 3)
                                       for t in THETAS},
                 "woc_fast_frac": {str(t): by[("woc", t)]["fast_frac"]
                                   for t in THETAS},
                 "independent_frac": {str(t): indep[t] for t in THETAS},
                 "theta_star": (round(theta_star, 3)
                                if theta_star != float("inf") else None),
                 "advantage_threshold": ADV_RATIO},
        "reads": {f"{p}@{rf}": read_rows[(p, rf)]["tx_s"]
                  for p in read_protos for rf in READ_FRACTIONS},
        "leases": {"total_ops": LEASE_TOTAL,
                   "protocols_with_lease_reads": lease_protos,
                   "read_sweep_tx": {str(rf): lease_rows[rf]["tx_s"]
                                     for rf in lease_rfs},
                   "read_sweep_local": {str(rf):
                                        lease_rows[rf]["read_local_frac"]
                                        for rf in lease_rfs},
                   "speedup_at_rf09": round(tx[-1] / tx[0], 3),
                   "churn_on_off_ratio": {str(t): round(cr[t], 3)
                                          for t in lease_thetas},
                   "churn_local_frac": {str(t): churn[t][0]
                                        ["read_local_frac"]
                                        for t in lease_thetas}},
        "arrivals": {"steady": steady, "bursty": bursty},
        "hotspot_drift": drift,
        "points": rows,
        "claims": claims.lines,
    })
    return claims.lines


# benchmarks/run.py invokes ``mod.run(out_dir, quick=...)`` on every suite
run = run_bench  # noqa: F811 — intentional module-entrypoint alias
