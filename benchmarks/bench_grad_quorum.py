"""Beyond-paper: weighted-quorum gradient commit vs full barrier.

The training-runtime adaptation of WOC's fast path: per-bucket gradients
commit at a strict weight majority of data-parallel workers instead of a
full barrier. Monte-Carlo over straggler profiles quantifies the step-time
cut (the training analog of the paper's commit-latency win)."""

from __future__ import annotations

import numpy as np

from benchmarks.common import Claims, write_csv
from repro.coord.grad_quorum import GradQuorum


def run(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    trials = 300 if quick else 1500
    profiles = [
        (16, "uniform"), (16, "one_slow"), (64, "one_slow"),
        (64, "tail_10pct"), (256, "tail_10pct"), (1024, "tail_10pct"),
    ]
    if quick:
        profiles = profiles[:4]
    rows = []
    for n, profile in profiles:
        base = np.ones(n)
        if profile == "one_slow":
            base[-1] = 3.0
        elif profile == "tail_10pct":
            base[-max(1, n // 10):] = 2.0
        gq = GradQuorum(n, t_fail=max(1, n // 8))
        for _ in range(20):                      # warm the latency EMA
            gq.observe(base * (0.9 + 0.2 * np.random.default_rng(0)
                               .random(n)))
        stats = gq.expected_step_time(base, trials=trials)
        mask = gq.commit_mask()
        w = gq.state.weights()
        wfrac = float(w[mask].sum() / w.sum())
        rows.append({"workers": n, "profile": profile,
                     "barrier_s": round(stats["barrier_mean_s"], 4),
                     "quorum_s": round(stats["quorum_mean_s"], 4),
                     "speedup": round(stats["speedup"], 3),
                     "committed_workers_frac": round(mask.mean(), 3),
                     "committed_weight_frac": round(wfrac, 3)})
    write_csv(out_dir, "grad_quorum_straggler", rows)

    worst = min(r["speedup"] for r in rows if r["profile"] != "uniform")
    claims.check("quorum commit cuts straggler tail (speedup > 1.2x "
                 "under skewed profiles)", worst > 1.2,
                 f"min straggler-profile speedup={worst:.2f}x")
    claims.check("committed WEIGHT is a strict majority (I2 analog)",
                 all(r["committed_weight_frac"] > 0.5 for r in rows),
                 f"weight fracs={[r['committed_weight_frac'] for r in rows]}")
    return claims.lines
