"""Aggregate dry-run JSONs into the EXPERIMENTS.md roofline table.

Usage: python -m benchmarks.roofline_report [--dir experiments/dryrun]
Prints a markdown table per mesh + a bottleneck summary and flags the
three §Perf hillclimb candidates (worst mfu-bound, most collective-bound,
most paper-representative).
"""

from __future__ import annotations

import argparse
import json
import pathlib


def load(dir_):
    recs = []
    for p in sorted(pathlib.Path(dir_).glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def fmt_row(r):
    if r["status"] != "OK":
        return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['status']} | — | — | — | — | — | — | "
                f"{r.get('reason', r.get('error', ''))[:60]} |")
    rf = r["roofline"]
    mem = r["memory"]["peak_estimate_per_device"] / 2**30
    return (f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK "
            f"| {rf['t_compute_s']:.2e} | {rf['t_memory_s']:.2e} "
            f"| {rf['t_collective_s']:.2e} | {rf['bottleneck']} "
            f"| {rf['useful_flops_ratio']:.2f} | {rf['mfu_bound']:.3f} "
            f"| {mem:.2f} GiB |")


HEADER = ("| arch | shape | mesh | status | t_comp (s) | t_mem (s) "
          "| t_coll (s) | bottleneck | useful/HLO | MFU bound | mem/dev |\n"
          "|---|---|---|---|---|---|---|---|---|---|---|")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default=None)
    args = ap.parse_args()
    recs = load(args.dir)
    print(HEADER)
    for r in recs:
        if args.mesh and r["mesh"] != args.mesh:
            continue
        print(fmt_row(r))

    ok = [r for r in recs if r["status"] == "OK" and r["mesh"] == "16x16"]
    if ok:
        worst = min(ok, key=lambda r: r["roofline"]["mfu_bound"])
        coll = max(ok, key=lambda r: r["roofline"]["t_collective_s"]
                   / max(max(r["roofline"]["t_compute_s"],
                             r["roofline"]["t_memory_s"]), 1e-30))
        over = [r for r in ok
                if r["memory"]["peak_estimate_per_device"] > 16 * 2**30]
        print(f"\nworst mfu_bound: {worst['arch']}/{worst['shape']} "
              f"({worst['roofline']['mfu_bound']:.4f})")
        print(f"most collective-bound: {coll['arch']}/{coll['shape']} "
              f"(t_coll/t_dom="
              f"{coll['roofline']['t_collective_s']:.2e})")
        print(f"cells over 16 GiB/dev: "
              f"{[(r['arch'], r['shape']) for r in over]}")


if __name__ == "__main__":
    main()
