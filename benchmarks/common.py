"""Shared benchmark plumbing: run protocols, write CSVs/JSON artifacts,
check claims, and the noisy-container measurement harness.

Measurement methodology (shared by bench_engine / bench_parallel_shard):
this container's CPU share fluctuates ~1.5x minute-to-minute, so lone
wall-clock samples are untrustworthy. Two tools compensate:

  * :func:`calibration_score` — a pure-Python machine-speed probe run in
    the same session as a recorded baseline constant; claims scale the
    constant by the probe ratio at claim time, making cross-machine
    comparisons approximately machine-independent.
  * :func:`paired_ab` — interleaved A/B/A/B runs with per-side medians:
    both sides sample the same noise regime, so the RATIO is stable even
    when the absolute numbers are not.
"""

from __future__ import annotations

import json
import pathlib
import statistics
import time

from repro.scenario import Scenario, Sharding, run_scenario


def calibration_score(iters: int = 300_000) -> float:
    """Machine-speed probe: interpreter ops/sec on an engine-like mix of
    dict traffic, int math, and bound-method-free loops. Baselines are
    recorded together with this score; claims scale them by the ratio of
    the probe at claim time, making the comparison approximately
    machine-independent."""
    best = 0.0
    for _ in range(3):
        d: dict = {}
        acc = 0
        t0 = time.perf_counter()
        for i in range(iters):
            k = (i * 0x9E3779B97F4A7C15) & 1023
            d[k] = i
            acc += d.get((k * 7) & 1023, 0)
        dt = time.perf_counter() - t0
        if dt > 0:
            best = max(best, iters / dt)
    return best


def paired_ab(run_a, run_b, repeats: int = 3, warmup: bool = True) -> dict:
    """Paired interleaved A/B wall-clock comparison.

    Runs ``A B A B ...`` (``repeats`` pairs) so scheduler-noise phases
    hit both sides equally, then reports per-side medians and the B/A
    speedup (``ratio`` > 1 means B is faster). ``run_a``/``run_b`` are
    zero-arg callables; their return values are discarded.
    """
    if warmup:
        run_a()
    a_s, b_s = [], []
    for _ in range(repeats):
        t0 = time.perf_counter()
        run_a()
        a_s.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        run_b()
        b_s.append(time.perf_counter() - t0)
    a_med = statistics.median(a_s)
    b_med = statistics.median(b_s)
    return {"a_s": [round(x, 4) for x in a_s],
            "b_s": [round(x, 4) for x in b_s],
            "a_median_s": round(a_med, 4),
            "b_median_s": round(b_med, 4),
            "ratio": round(a_med / b_med, 4) if b_med > 0 else float("inf")}


def scenario_point(sc: Scenario) -> dict:
    """Run one flat Scenario and flatten its result into a sweep row.
    Every bench suite constructs its runs through here (or
    :func:`sharded_point`), so the Scenario spec is the single
    experiment-construction path in the tree."""
    t0 = time.time()
    r = run_scenario(sc).result
    return {"protocol": r.protocol, "n": r.n_replicas,
            "clients": r.n_clients, "batch": r.batch_size,
            "tx_s": round(r.throughput_tx_s, 1),
            "avg_ms": round(r.latency_avg_ms, 4),
            "p50_ms": round(r.latency_p50_ms, 4),
            "p99_ms": round(r.latency_p99_ms, 4),
            "fast_frac": round(r.fast_path_frac, 4),
            "ops": r.committed_ops,
            "wall_s": round(time.time() - t0, 1)}


def run_point(**kw) -> dict:
    """Scenario fields as kwargs -> one flat sweep row (legacy-shaped
    helper shared by the §5 figure suites)."""
    return scenario_point(Scenario(**kw))


def sharded_point(sharding: Sharding, **kw) -> dict:
    """Run one sharded Scenario and flatten its ShardedRunResult into a
    sweep row (shared by the shard/parallel suites)."""
    r = run_scenario(Scenario(sharding=sharding, **kw)).result
    return {"protocol": r.protocol, "groups": r.n_groups,
            "group_size": r.group_size, "clients": r.n_clients,
            "batch": r.batch_size, "locality": r.locality,
            "ops": r.committed_ops, "tx_s": round(r.throughput_tx_s, 1),
            "p50_ms": round(r.latency_p50_ms, 4),
            "p99_ms": round(r.latency_p99_ms, 4),
            "fast_frac": round(r.fast_path_frac, 4),
            "remote_frac": round(r.remote_frac, 4),
            "redirect_rate": round(r.redirect_rate, 5),
            "migrations": r.migrations, "steal_hints": r.steal_hints,
            "messages": r.messages}


def write_csv(out_dir, name: str, rows: list[dict]) -> pathlib.Path:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{name}.csv"
    if rows:
        cols = list(dict.fromkeys(c for r in rows for c in r))
        lines = [",".join(cols)]
        lines += [",".join(str(r.get(c, "")) for c in cols) for r in rows]
        path.write_text("\n".join(lines) + "\n")
    return path


def write_json(out_dir, name: str, payload: dict) -> pathlib.Path:
    """Write a trajectory artifact (e.g. BENCH_shard.json): a structured
    snapshot of a benchmark's sweep + claims for cross-PR comparison."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
    return path


class Claims:
    """Collects paper-claim validations for the summary report."""

    def __init__(self):
        self.lines: list[str] = []

    def check(self, name: str, ok: bool, detail: str):
        mark = "PASS" if ok else "MISS"
        self.lines.append(f"[{mark}] {name}: {detail}")
        return ok

    def note(self, name: str, detail: str):
        """Informational line: recorded in reports/artifacts but never
        fails the driver (used for machine-dependent comparisons in
        --quick mode, where CI hardware differs from the machine the
        baseline constant was measured on)."""
        self.lines.append(f"[NOTE] {name}: {detail}")
