"""Shared benchmark plumbing: run protocols, write CSVs/JSON artifacts,
check claims."""

from __future__ import annotations

import json
import pathlib
import time

from repro.core.runner import RunConfig, run


def run_point(**kw) -> dict:
    t0 = time.time()
    art = run(RunConfig(**kw))
    r = art.result
    return {"protocol": r.protocol, "n": r.n_replicas,
            "clients": r.n_clients, "batch": r.batch_size,
            "tx_s": round(r.throughput_tx_s, 1),
            "avg_ms": round(r.latency_avg_ms, 4),
            "p50_ms": round(r.latency_p50_ms, 4),
            "p99_ms": round(r.latency_p99_ms, 4),
            "fast_frac": round(r.fast_path_frac, 4),
            "ops": r.committed_ops,
            "wall_s": round(time.time() - t0, 1)}


def write_csv(out_dir, name: str, rows: list[dict]) -> pathlib.Path:
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{name}.csv"
    if rows:
        cols = list(rows[0])
        lines = [",".join(cols)]
        lines += [",".join(str(r[c]) for c in cols) for r in rows]
        path.write_text("\n".join(lines) + "\n")
    return path


def write_json(out_dir, name: str, payload: dict) -> pathlib.Path:
    """Write a trajectory artifact (e.g. BENCH_shard.json): a structured
    snapshot of a benchmark's sweep + claims for cross-PR comparison."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    path = out / f"{name}.json"
    path.write_text(json.dumps(payload, indent=2, default=str) + "\n")
    return path


class Claims:
    """Collects paper-claim validations for the summary report."""

    def __init__(self):
        self.lines: list[str] = []

    def check(self, name: str, ok: bool, detail: str):
        mark = "PASS" if ok else "MISS"
        self.lines.append(f"[{mark}] {name}: {detail}")
        return ok

    def note(self, name: str, detail: str):
        """Informational line: recorded in reports/artifacts but never
        fails the driver (used for machine-dependent comparisons in
        --quick mode, where CI hardware differs from the machine the
        baseline constant was measured on)."""
        self.lines.append(f"[NOTE] {name}: {detail}")
