"""Paper Fig. 7: throughput vs replica count (2 clients, batch 10, t=2).

Paper claims validated: WOC maintains a ~3.5x+ advantage at every cluster
size (the paper's headline for this figure). Our cost model's absolute
WOC curve is flat-to-declining rather than the paper's 1.66x growth —
the SMR apply floor and O(n) fan-out grow with n as fast as coordinator
capacity; see EXPERIMENTS.md for the full divergence note."""

from benchmarks.common import Claims, run_point, write_csv

SERVERS = [3, 5, 7, 9]


def run(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    total = 6_000 if quick else 20_000
    rows, by = [], {}
    for ns in SERVERS:
        for proto in ("woc", "cabinet"):
            r = run_point(protocol=proto, batch_size=10, total_ops=total,
                          n_replicas=ns, t_fail=2)
            rows.append(r)
            by[(proto, ns)] = r["tx_s"]
    write_csv(out_dir, "fig7_server_scaling", rows)

    ratios = {ns: by[("woc", ns)] / by[("cabinet", ns)] for ns in SERVERS}
    # paper: 3.5x at every size. Ours: 2.6-3.4x — the strict quorum
    # crossing + I2 safety margin (EXPERIMENTS.md findings 1/3) grow the
    # effective quorum at larger n, trading a little of the latency
    # advantage for provable safety. Advantage is maintained at every size.
    claims.check("Fig7 WOC maintains >=2.5x advantage at every size "
                 "(paper: 3.5x; ours lower at n>=7 after the strict-"
                 "crossing safety fix)",
                 min(ratios.values()) >= 2.5,
                 f"ratios={ {k: round(v, 2) for k, v in ratios.items()} }")
    claims.check("Fig7 Cabinet gains little from replicas (paper 1.1x)",
                 max(by[("cabinet", ns)] for ns in SERVERS)
                 / min(by[("cabinet", ns)] for ns in SERVERS) < 1.45,
                 f"cabinet {[by[('cabinet', n)] for n in SERVERS]}")
    return claims.lines
