"""Paper Fig. 6: throughput vs concurrent clients (5 servers, batch 10).

Paper claims validated: WOC grows with client count (distributed
ingestion); Cabinet flat at its leader bound regardless of clients."""

from benchmarks.common import Claims, run_point, write_csv

CLIENTS = [2, 3, 5, 7, 9]


def run(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    total = 6_000 if quick else 20_000
    rows, by = [], {}
    for nc in CLIENTS:
        for proto in ("woc", "cabinet"):
            r = run_point(protocol=proto, batch_size=10, total_ops=total,
                          n_clients=nc)
            rows.append(r)
            by[(proto, nc)] = r["tx_s"]
    write_csv(out_dir, "fig6_client_scaling", rows)

    growth = by[("woc", 9)] / by[("woc", 2)]
    claims.check("Fig6 WOC grows with clients (paper 2.3x; queueing-"
                 "regime difference noted in EXPERIMENTS.md)",
                 growth >= 1.15, f"2->9 clients growth={growth:.2f}x")
    cab = [by[("cabinet", c)] for c in CLIENTS]
    claims.check("Fig6 Cabinet flat (paper: 15-16k at every client count)",
                 max(cab) / min(cab) < 1.15,
                 f"cabinet range {min(cab):.0f}-{max(cab):.0f}")
    adv = min(by[("woc", c)] / by[("cabinet", c)] for c in CLIENTS)
    claims.check("Fig6 WOC advantage at every client count",
                 adv >= 2.0, f"min ratio={adv:.2f}")
    return claims.lines
