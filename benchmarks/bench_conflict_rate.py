"""Paper Fig. 5: throughput / latency vs conflict rate (batch 10).

Paper claims validated: ~3.8x at 0-10% conflicts with >95% fast-path
commits; monotone degradation; crossover (WOC <= Cabinet) by 75-100%;
Cabinet flat across all rates."""

from benchmarks.common import Claims, run_point, write_csv
from repro.core.simulator import Workload

RATES = [0.0, 0.02, 0.10, 0.25, 0.50, 0.75, 1.00]


def run(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    total = 4_000 if quick else 12_000
    rows = []
    by = {}
    for rate in RATES:
        w = Workload(p_independent=1 - rate, p_common=0.0, p_hot=rate)
        for proto in ("woc", "cabinet"):
            r = run_point(protocol=proto, batch_size=10, total_ops=total,
                          workload=w)
            r["conflict"] = rate
            rows.append(r)
            by[(proto, rate)] = r
    write_csv(out_dir, "fig5_conflict_rate", rows)

    r0 = by[("woc", 0.0)]["tx_s"] / by[("cabinet", 0.0)]["tx_s"]
    claims.check("Fig5 low-conflict advantage (paper ~3.8x)", r0 >= 3.0,
                 f"0% ratio={r0:.2f}")
    claims.check("Fig5 >95% fast-path commits at 0% conflict",
                 by[("woc", 0.0)]["fast_frac"] > 0.95,
                 f"fast_frac={by[('woc', 0.0)]['fast_frac']:.3f}")
    r100 = by[("woc", 1.0)]["tx_s"] / by[("cabinet", 1.0)]["tx_s"]
    claims.check("Fig5 crossover at full contention (paper: Cabinet wins)",
                 r100 <= 1.1, f"100% ratio={r100:.2f}")
    cab = [by[("cabinet", x)]["tx_s"] for x in RATES]
    claims.check("Fig5 Cabinet conflict-insensitive (paper: flat 15-16k)",
                 max(cab) / min(cab) < 1.25,
                 f"cabinet range {min(cab):.0f}-{max(cab):.0f}")
    woc = [by[("woc", x)]["tx_s"] for x in RATES]
    claims.check("Fig5 WOC degrades monotonically with contention",
                 all(woc[i] >= woc[i + 1] * 0.9 for i in range(len(woc) - 1)),
                 f"woc curve {[int(x) for x in woc]}")
    return claims.lines
