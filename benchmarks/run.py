"""Benchmark driver: one module per paper table/figure + beyond-paper
benches. Writes CSVs to experiments/bench/ and prints a paper-claim
validation summary.
``python -m benchmarks.run [--quick] [--only NAME] [--jobs N]``

``--quick`` threads a reduced-size mode through every suite (smaller
sweeps, fewer ops/batches/trials) so CI smoke steps and laptops can run
the full driver in minutes instead of hours. Quick mode trades
claim-validation fidelity for speed: the reduced runs sit in noisier
queueing regimes, so treat quick-mode [MISS] lines as a prompt to re-run
the full suite, not as a regression verdict. The ``engine`` suite is the
exception — its claims are sized to hold in quick mode (CI runs
``--quick --only engine``).
"""

from __future__ import annotations

import argparse
import inspect
import sys
import time

from benchmarks import (bench_batch_size, bench_client_scaling,
                        bench_conflict_rate, bench_engine,
                        bench_fault_recovery, bench_grad_quorum,
                        bench_parallel_shard, bench_payload,
                        bench_quorum_kernel, bench_server_scaling,
                        bench_shard_scaling, bench_weights,
                        bench_workloads)

SUITES = [
    ("engine", bench_engine),
    ("weights_tables", bench_weights),
    ("quorum_kernel", bench_quorum_kernel),
    ("grad_quorum", bench_grad_quorum),
    ("conflict_rate", bench_conflict_rate),
    ("batch_size", bench_batch_size),
    ("client_scaling", bench_client_scaling),
    ("server_scaling", bench_server_scaling),
    ("workloads", bench_workloads),
    ("shard_scaling", bench_shard_scaling),
    ("parallel", bench_parallel_shard),
    ("payload", bench_payload),
    ("faults", bench_fault_recovery),
]


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="experiments/bench")
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="reduced batches/clients/sweeps in every suite "
                         "(CI smoke / laptop mode)")
    ap.add_argument("--jobs", type=int, default=0,
                    help="worker processes for parallel-simulation suites "
                         "(0 = auto: min(groups, cores)); suites that do "
                         "not take a jobs parameter ignore it")
    ap.add_argument("--trace", action="store_true",
                    help="export Perfetto-loadable TRACE_*.json span "
                         "artifacts from trace-aware suites (see "
                         "repro.obs); suites that do not take a trace "
                         "parameter ignore it")
    args = ap.parse_args()

    all_lines = []
    t00 = time.time()
    for name, mod in SUITES:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        print(f"=== {name} ===", flush=True)
        kwargs = {"quick": args.quick}
        params = inspect.signature(mod.run).parameters
        if "jobs" in params:
            kwargs["jobs"] = args.jobs
        if "trace" in params:
            kwargs["trace"] = args.trace
        lines = mod.run(args.out, **kwargs)
        for ln in lines:
            print("  " + ln, flush=True)
        print(f"  ({time.time()-t0:.0f}s)", flush=True)
        all_lines += lines

    misses = [l for l in all_lines if l.startswith("[MISS]")]
    print(f"\n=== paper-claim validation: "
          f"{len(all_lines) - len(misses)}/{len(all_lines)} PASS "
          f"({time.time()-t00:.0f}s total) ===")
    for m in misses:
        print("  " + m)
    return 1 if misses else 0


if __name__ == "__main__":
    sys.exit(main())
