"""Payload striping: striped vs full-copy cost across value size and
contention (repro.coding — the Crossword data-heavy evaluation).

Replication cost for a large value is the wire time of shipping it to
every replica: a full copy pays ``(n-1) * size`` bytes at the
coordinator's NIC, an RS(k, m) stripe pays ``(k+m) * size/k`` — a
~k/(k+m)-fold byte reduction that turns directly into throughput when
per-byte costs dominate the op budget. The sweep runs the same
data-heavy workload with the ``Scenario.coding`` knob on and off across
a value-size ladder at low contention (independent objects, the regime
striping targets) plus a high-contention twin at the largest size
(hot-object conflicts serialize on the consensus path, so striping can
at best hold parity there — the claim is that it costs nothing).

The adaptive floor is part of the story: at sub-threshold sizes the
policy ships classic full copies even with the knob on, so the
smallest rung must land at parity BY DECISION (striped_frac == 0), not
by luck.

Every run's history is verified linearizable before any number is
reported; byte costs are explicit CostModel terms, so the ratios are
deterministic functions of seed + schedule, not wall-clock noise.
"""

from benchmarks.common import Claims, write_csv, write_json

from repro.core.simulator import CostModel
from repro.scenario import (Coding, Scenario, ValueSizesWorkload,
                            ZipfWorkload, run_scenario)
from repro.verify import check_history_linearizable

# a 2 Gbit/s-class NIC serialization term + a cheaper receive-side parse:
# large enough that a 1 MiB full copy dominates its op budget, small
# enough that metadata traffic stays fixed-cost shaped
COSTS = CostModel(c_byte_wire=4e-9, c_byte_parse=1e-9)

SIZES = (2 << 10, 1 << 16, 1 << 18)            # 2 KiB, 64 KiB, 256 KiB
# (256 KiB is the ladder top by design: above it a 4-op full-copy
# batch's serialization alone approaches the 30 ms fast-path timeout
# and the run degenerates into retry livelock — that regime belongs to
# chunked transfer, not bigger frames)
SMALLEST = SIZES[0]                            # under stripe_min_bytes
LARGEST = SIZES[-1]


def _workload(contention: str, size: int):
    n_objects = 8 if contention == "high" else 512
    return ValueSizesWorkload(
        base=ZipfWorkload(n_objects=n_objects, theta=0.0,
                          reads_fraction=0.5),
        size_dist="fixed", size_small=size)


def _run(size: int, contention: str, coding: bool, total_ops: int,
         claims: Claims) -> dict:
    art = run_scenario(Scenario(
        protocol="woc", n_replicas=5, n_clients=4, batch_size=4,
        total_ops=total_ops, seed=7, costs=COSTS,
        workload=_workload(contention, size),
        coding=Coding() if coding else None))
    r = art.result
    ok, why = check_history_linearizable(r.history)
    claims.check(
        f"payload/{contention}/{size}B/"
        f"{'striped' if coding else 'full'}: all ops commit, history "
        f"linearizable",
        ok and r.committed_ops == total_ops,
        f"committed={r.committed_ops}/{total_ops} "
        f"{'ok' if ok else why}")
    return {"size_bytes": size, "contention": contention,
            "coding": coding, "ops": r.committed_ops,
            "tx_s": round(r.throughput_tx_s, 1),
            "makespan_s": round(r.makespan_s, 4),
            "striped_frac": round(r.striped_frac, 4),
            "fast_frac": round(r.fast_path_frac, 4)}


def run_bench(out_dir, quick: bool = False) -> list[str]:
    claims = Claims()
    total = 1000 if quick else 2500

    rows = []
    by = {}
    for size in SIZES:
        for coding in (False, True):
            row = _run(size, "low", coding, total, claims)
            rows.append(row)
            by[("low", size, coding)] = row
    for coding in (False, True):
        row = _run(LARGEST, "high", coding, total, claims)
        rows.append(row)
        by[("high", LARGEST, coding)] = row

    # -- the Crossword claim: striping pays at scale ------------------------
    big_on = by[("low", LARGEST, True)]
    big_off = by[("low", LARGEST, False)]
    ratio_big = big_on["tx_s"] / max(big_off["tx_s"], 1e-9)
    claims.check(
        f"Largest size ({LARGEST}B), low contention: striped throughput "
        f">= 1.5x full-copy (the k/(k+m) byte reduction dominates)",
        ratio_big >= 1.5 and big_on["striped_frac"] > 0.0,
        f"striped={big_on['tx_s']} full={big_off['tx_s']} "
        f"ratio={ratio_big:.2f}x striped_frac={big_on['striped_frac']}")

    hi_on = by[("high", LARGEST, True)]
    hi_off = by[("high", LARGEST, False)]
    ratio_hi = hi_on["tx_s"] / max(hi_off["tx_s"], 1e-9)
    claims.check(
        "Largest size, high contention: striping holds parity (>= 0.9x) "
        "where conflicts, not bytes, bound throughput",
        ratio_hi >= 0.9,
        f"striped={hi_on['tx_s']} full={hi_off['tx_s']} "
        f"ratio={ratio_hi:.2f}x")

    small_on = by[("low", SMALLEST, True)]
    small_off = by[("low", SMALLEST, False)]
    ratio_small = small_on["tx_s"] / max(small_off["tx_s"], 1e-9)
    claims.check(
        f"Adaptive floor: {SMALLEST}B values never stripe (below "
        f"stripe_min_bytes) and land at full-copy parity",
        small_on["striped_frac"] == 0.0 and 0.95 <= ratio_small <= 1.05,
        f"striped_frac={small_on['striped_frac']} "
        f"ratio={ratio_small:.2f}x")

    # the ladder should be monotone-ish: the bigger the value, the bigger
    # striping's payoff (ratios reported for the trajectory either way)
    ladder = {s: round(by[("low", s, True)]["tx_s"]
                       / max(by[("low", s, False)]["tx_s"], 1e-9), 3)
              for s in SIZES}
    claims.check(
        "Striping payoff grows with value size across the ladder",
        ladder[SIZES[-1]] >= ladder[SIZES[1]] >= ladder[SIZES[0]] - 0.05,
        f"ratios={ladder}")

    write_csv(out_dir, "payload_striping", rows)
    write_json(out_dir, "BENCH_payload", {
        "bench": "payload",
        "quick": quick,
        "costs": {"c_byte_wire": COSTS.c_byte_wire,
                  "c_byte_parse": COSTS.c_byte_parse},
        "sizes": list(SIZES),
        "points": rows,
        "ratios": {"low_contention_by_size": ladder,
                   "high_contention_largest": round(ratio_hi, 3)},
        "claims": claims.lines,
    })
    return claims.lines


# benchmarks/run.py invokes ``mod.run(out_dir)`` on every suite module
run = run_bench  # noqa: F811 — intentional module-entrypoint alias
