"""Paper Tables 1-2: geometric weight distributions + invariants."""

import numpy as np

from benchmarks.common import Claims, write_csv
from repro.core import weights as W


def run(out_dir, quick: bool = False) -> list[str]:
    # pure closed-form math — already instant, quick mode changes nothing
    claims = Claims()
    rows = []
    # Table 1 (object weights)
    for label, r, t in [("ObjA", 1.40, 1), ("ObjB", 1.38, 1),
                        ("ObjC", 1.25, 2), ("ObjD", 1.10, 3)]:
        w = np.asarray(W.geometric_weights(7, r))
        rows.append({"table": 1, "row": label, "R": r, "t": t,
                     **{f"w{i+1}": round(float(x), 2)
                        for i, x in enumerate(w)},
                     "T": round(float(w.sum() / 2), 2),
                     "I1": bool(W.check_invariant_progress(w, t)),
                     "I2": bool(W.check_invariant_safety(w, t))})
    # Table 2 (node weights)
    for t, r in [(1, 1.40), (2, 1.38), (3, 1.19), (4, 1.08)]:
        w = np.asarray(W.geometric_weights(7, r))
        rows.append({"table": 2, "row": f"t={t}", "R": r, "t": t,
                     **{f"w{i+1}": round(float(x), 2)
                        for i, x in enumerate(w)},
                     "T": round(float(w.sum() / 2), 2),
                     "I1": bool(W.check_invariant_progress(w, t)),
                     "I2": bool(W.check_invariant_safety(w, t))})
    write_csv(out_dir, "tables_1_2_weights", rows)

    obja = np.asarray(W.geometric_weights(7, 1.40))
    claims.check("Table1 ObjA weights", bool(
        np.allclose(obja, [7.53, 5.38, 3.84, 2.74, 1.96, 1.40, 1.00],
                    atol=0.005)),
        f"w={np.round(obja, 2).tolist()} T={obja.sum()/2:.2f} (paper 11.93)")
    claims.check("I1 (progress) holds for every table row",
                 all(r["I1"] for r in rows), "top t+1 weights exceed T")
    t1_rows = [r for r in rows if r["t"] == 1]
    claims.check("I2 (safety) holds for all t=1 rows",
                 all(r["I2"] for r in t1_rows), "top-1 weight below T")
    # FINDING: the paper's printed steepness for t>=2 rows violates its own
    # Invariant I2 (e.g. Table 2 t=2, R=1.38: top-2 = 11.91 > T = 11.23).
    # We derive the actual feasible suprema with solve_steepness and use
    # those in the protocol; the violation is recorded, not asserted away.
    viol = [r["row"] for r in rows if r["t"] >= 2 and not r["I2"]]
    fix = {t: round(W.solve_steepness(7, t), 4) for t in (2, 3)}
    claims.check("paper t>=2 rows I2 status recorded (known paper "
                 "inconsistency; feasible R derived)",
                 True, f"violating rows={viol}; feasible R={fix}")
    return claims.lines
